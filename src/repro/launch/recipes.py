"""Deployment recipes: per-(arch x shape) sharding/optimizer/microbatch knobs.

This is the XaaS provider-side tuning table — the paper's "system-specific
set of accelerated APIs ... tuned to each target system and maintained by the
provider" generalized to whole deployment recipes. The container (model
recipe) is portable; THIS file is what the provider specializes per target.

Every knob is memory-arithmetic-driven for the fixed v5e pod (16 GB/chip,
256 chips single pod); the reasoning is recorded per arch below and in
DESIGN.md §4. The dry-run validates the arithmetic via memory_analysis().
"""
from __future__ import annotations

import dataclasses

from repro.configs import base as cfgbase
from repro.distributed import sharding as shd

__all__ = ["Recipe", "recipe_for", "rules_for", "train_config_for"]


@dataclasses.dataclass(frozen=True)
class Recipe:
    # params additionally sharded over "data" on their hidden dim (FSDP /
    # ZeRO-3 style; XLA SPMD inserts the per-layer all-gather)
    fsdp: bool = False
    # extend FSDP over the pod (DCN) axis too: required when params+opt
    # arithmetic exceeds one pod (671B: 5.2 GB/chip params alone at 256-way;
    # grads+accumulator push past 16 GB — cross-pod ZeRO-3 halves all of it
    # at the cost of DCN param gathers, which the roofline prices honestly)
    fsdp_pod: bool = False
    # MoE experts sharded over (data, model) = full-mesh EP (256-way)
    ep2d: bool = False
    # <2B archs in training: treat the whole mesh as a DP farm
    # (batch over data x model, params replicated). 16-way TP of a 0.5B
    # model whose 14 heads don't divide the model axis costs ~16x replicated
    # attention + per-layer resharding all-gathers — measured 41.7 GiB/step
    # of ICI traffic vs ~2 GiB for the grad all-reduce under DP-only.
    dp_only: bool = False
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over "model" on the sequence dim (saves the
    # layer-boundary activation carries: 3.5 GB/chip -> 0.22 GB at 671B)
    seq_parallel: bool = False
    # pad attention heads to a multiple of the model-axis size so head
    # counts like 56/40/24 shard 16 ways instead of replicating the whole
    # attention computation on every model rank (§Perf hillclimb A)
    pad_heads: bool = False
    # decode: replicate the tiny per-token activations and keep weights
    # stationary (contract over the data-sharded param dim + psum) instead
    # of FSDP-gathering whole layers per token (§Perf hillclimb C)
    decode_2d_tp: bool = False
    optimizer: str = "adamw"  # adamw | adafactor
    accum_dtype: str = "float32"
    momentum: float = 0.0  # adafactor only
    # microbatch sizing: sequences per chip per microbatch (grad accumulation
    # splits the global batch so per-mb global batch = dp_degree * this)
    mb_seqs_per_chip: int = 2
    remat: str = "full"
    # serving: KV-cache sequence axis sharding ("model" = flash-decoding
    # style sequence split; batch is already on "data")
    kv_seq_axis: str | None = "model"
    dcn_compression: str = "mean"  # baseline: plain pjit all-reduce
    notes: str = ""


# ---------------------------------------------------------------------------
# Per-arch base recipes (training knobs; serving derives from them)
# ---------------------------------------------------------------------------
_R = Recipe
_ARCH_RECIPES: dict[str, Recipe] = {
    # <2B: whole mesh as DP farm for training (params replicated), AdamW f32
    "qwen2-0.5b": _R(dp_only=True, mb_seqs_per_chip=1),
    "xlstm-1.3b": _R(dp_only=True, mb_seqs_per_chip=1,
                     notes="mLSTM chunk scan carries (C,n,m) f32 per chunk"),
    "musicgen-medium": _R(dp_only=True, mb_seqs_per_chip=1),
    # 9-16B: FSDP params (per-layer all-gather), AdamW + ZeRO-1
    "qwen2.5-14b": _R(fsdp=True, mb_seqs_per_chip=2),
    "recurrentgemma-9b": _R(fsdp=True, mb_seqs_per_chip=2),
    "moonshot-v1-16b-a3b": _R(fsdp=True, mb_seqs_per_chip=2,
                              notes="64 experts on model axis (4/chip)"),
    # 34B: FSDP, 1 seq/chip microbatches (60-88 layer activation carries)
    "llava-next-34b": _R(fsdp=True, mb_seqs_per_chip=1,
                         notes="train seq = 4096 text + 2928 image tokens"),
    "granite-34b": _R(fsdp=True, mb_seqs_per_chip=1),
    # 104B: FSDP mandatory (params/16 = 13 GB > budget without it)
    "command-r-plus-104b": _R(fsdp=True, mb_seqs_per_chip=1),
    # 671B: full-mesh EP for the 653B routed params (5.1 GB/chip), FSDP for
    # the dense 18B, Adafactor (AdamW m+v f32 = 21 GB/chip > 16 GB HBM — no
    # sharding fixes that arithmetic), bf16 grad accumulation
    # NOTE: seq_parallel=True was tried here and REFUTED — a global
    # seq->model rule makes XLA reshard at every constraint site (4x flops,
    # 38 TB ICI). Recorded in EXPERIMENTS.md §Perf.
    "deepseek-v3-671b": _R(fsdp=True, fsdp_pod=True, ep2d=True,
                           optimizer="adafactor", accum_dtype="bfloat16",
                           mb_seqs_per_chip=1,
                           notes="PaLM-style factored optimizer; see DESIGN §4"),
}


def recipe_for(arch_id: str, shape_id: str) -> Recipe:
    r = _ARCH_RECIPES[arch_id]
    shape = cfgbase.SHAPES[shape_id]
    if shape.kind != "train":
        # serving: optimizer/microbatch knobs are irrelevant
        r = dataclasses.replace(
            r, optimizer="adamw", accum_dtype="float32", mb_seqs_per_chip=1)
    # §Perf variant overrides (hillclimb harness):
    #   XAAS_RECIPE_OVERRIDES='{"llava-next-34b": {"pad_heads": true}}'
    ov = _env_overrides().get(arch_id)
    if ov:
        r = dataclasses.replace(r, **ov)
    return r


def _env_overrides() -> dict:
    import json
    import os

    raw = os.environ.get("XAAS_RECIPE_OVERRIDES", "")
    return json.loads(raw) if raw else {}


# ---------------------------------------------------------------------------
# Sharding rules under a recipe
# ---------------------------------------------------------------------------
def rules_for(recipe: Recipe, *, multi_pod: bool, serving: bool) -> shd.Rules:
    rules = dict(shd.RULES_3D if multi_pod else shd.RULES_2D)
    if recipe.dp_only and not serving:
        batch = ("pod", "data", "model") if multi_pod else ("data", "model")
        for k, v in rules.items():
            if v is not None and k != "batch":
                rules[k] = None
        rules["batch"] = batch
        rules["expert_group"] = batch
        return rules
    if recipe.fsdp:
        rules["p_embed"] = ("pod", "data") if (
            multi_pod and recipe.fsdp_pod) else "data"
    if recipe.ep2d:
        rules["experts"] = ("data", "model")
        # token dispatch groups stay on the batch axes; the expert_cap dim of
        # the (E, B*C, D) all-to-all layout is left unsharded (E covers the
        # full mesh)
    if recipe.seq_parallel and not serving:
        rules["seq"] = "model"
    if recipe.pad_heads:
        rules["__pad_heads__"] = 16  # model-axis size (assignment-fixed)
    if serving and recipe.kv_seq_axis:
        rules["kv_seq"] = recipe.kv_seq_axis
    if serving and recipe.decode_2d_tp:
        rules["batch"] = None  # activations replicated; cache keeps
        # state_batch -> data; params contract over p_embed -> data + psum
    return rules


def train_config_for(cfg, recipe: Recipe, *, mesh, multi_pod: bool):
    """Build the TrainConfig for one (arch, train shape, mesh) cell."""
    from repro.training import train_step as ts

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    shape = cfgbase.SHAPES["train_4k"]
    # effective DP = longest prefix of the batch axes that divides the
    # global batch (mirrors guarded_spec's tuple degrade)
    axes = ["pod", "data"] if multi_pod else ["data"]
    if recipe.dp_only:
        axes.append("model")
    dp = 1
    for a in axes:
        nxt = dp * sizes.get(a, 1)
        if shape.global_batch % nxt == 0:
            dp = nxt
    per_mb = dp * recipe.mb_seqs_per_chip
    micro = max(1, shape.global_batch // per_mb)
    return ts.TrainConfig(
        optimizer=recipe.optimizer,
        adafactor=dataclasses.replace(
            ts.opt.AdafactorConfig(), momentum=recipe.momentum),
        accum_dtype=recipe.accum_dtype,
        microbatches=micro,
        remat=recipe.remat,
        dcn_compression=recipe.dcn_compression if multi_pod else "mean",
    )
