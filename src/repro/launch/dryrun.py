import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
# The 512 stand-in host devices exist ONLY in this process — smoke tests and
# benches see the real single device.
#
# Multi-pod dry-run driver (assignment deliverable (e)):
#   for every (architecture x input shape) cell and each production mesh,
#   lower + compile the step program, print memory/cost analysis, parse the
#   collective schedule out of the optimized HLO, and record everything to
#   results/dryrun/<mesh>/<arch>__<shape>.json for §Roofline / §Perf.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
#       --shape train_4k --mesh single
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
import argparse
import dataclasses
import json
import pathlib
import re
import time
import traceback

import jax

from repro import configs
from repro.configs import base as cfgbase
from repro.launch import cells as cellslib
from repro.launch import hlo_cost
from repro.launch import mesh as meshlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# Analytic model FLOPs (roofline "useful compute" numerator)
# ---------------------------------------------------------------------------
def model_flops(arch_id: str, shape_id: str) -> float:
    cfg = configs.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_id]
    counts = cfg.param_counts()
    n = counts["active_nonembed"]  # 6*N*D convention: non-embedding, active
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if cfg.frontend == "vlm" and shape.kind != "decode":
        tokens += shape.global_batch * cfg.num_image_tokens
    per_token = 6 * n if shape.kind == "train" else 2 * n
    return float(per_token) * tokens


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------
def run_cell(arch_id: str, shape_id: str, mesh_kind: str,
             *, out_dir: pathlib.Path = RESULTS, verbose: bool = True,
             tag: str = "", hook_overrides: dict | None = None) -> dict:
    multi_pod = mesh_kind == "multi"
    rec: dict = {
        "arch": arch_id, "shape": shape_id, "mesh": mesh_kind, "tag": tag,
        "chips": 512 if multi_pod else 256,
    }
    cfg = configs.get_config(arch_id)
    shape = cfgbase.SHAPES[shape_id]
    ok, why = cfgbase.shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        _write(rec, out_dir, tag)
        if verbose:
            print(f"[skip] {arch_id} x {shape_id} ({mesh_kind}): {why}")
        return rec

    try:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
        t0 = time.perf_counter()
        cell = cellslib.build_cell(arch_id, shape_id, mesh,
                                   hook_overrides=hook_overrides)
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        lowered = cell.lower()
        lower_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

        cost = hlo_cost.xla_cost_analysis(compiled)
        mem = None
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                mem = {
                    k: int(getattr(ma, k))
                    for k in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes", "generated_code_size_in_bytes",
                              "alias_size_in_bytes")
                    if hasattr(ma, k)
                }
        except Exception as e:  # CPU backend may not implement it
            mem = {"error": str(e)}
        text = compiled.as_text()
        # loop-aware per-device cost (primary roofline source; raw XLA
        # cost_analysis under-counts while-loop bodies — see hlo_cost.py)
        walk = hlo_cost.analyze(text)
        mf = model_flops(arch_id, shape_id)
        flops = walk.flops
        rec.update(
            status="ok",
            build_s=round(build_s, 3), lower_s=round(lower_s, 3),
            compile_s=round(compile_s, 3),
            hlo_cost=walk.to_dict(),
            xla_cost={k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float)) and k in
                      ("flops", "bytes accessed", "transcendentals")},
            memory=mem,
            model_flops=mf,
            microbatches=cell.meta.get("microbatches"),
            recipe=dataclasses.asdict(cell.meta["recipe"]),
            hlo_bytes=len(text),
        )
        if verbose:
            args_gb = (mem or {}).get("argument_size_in_bytes", 0) / 2**30
            temp_gb = (mem or {}).get("temp_size_in_bytes", 0) / 2**30
            useful = mf / max(flops * rec["chips"], 1e-30)
            print(f"[ok] {arch_id} x {shape_id} ({mesh_kind}): "
                  f"lower {lower_s:.1f}s compile {compile_s:.1f}s | "
                  f"flops/dev {flops:.3e} useful {useful:.2f} | "
                  f"coll ici {walk.collective_bytes('ici') / 2**30:.2f} "
                  f"dcn {walk.collective_bytes('dcn') / 2**30:.2f} GiB | "
                  f"mem args {args_gb:.2f} + temps {temp_gb:.2f} GiB/dev")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[ERR] {arch_id} x {shape_id} ({mesh_kind}): {e}")
    _write(rec, out_dir, tag)
    return rec


def _write(rec: dict, out_dir: pathlib.Path, tag: str = "") -> None:
    d = out_dir / rec["mesh"]
    d.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = d / f"{rec['arch']}__{rec['shape']}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(configs.ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(cfgbase.SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--tag", default="", help="variant tag for §Perf runs")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        pairs = cellslib.cell_ids()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs = [(args.arch, args.shape)]

    out_dir = pathlib.Path(args.out)
    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch_id, shape_id in pairs:
            rec = run_cell(arch_id, shape_id, mesh_kind, out_dir=out_dir,
                           tag=args.tag)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
            jax.clear_caches()  # executables otherwise accumulate over 80 cells
    print(f"dry-run: {n_ok} ok, {n_skip} documented skips, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
