"""repro: XaaS — Acceleration as a Service — as a JAX/TPU framework."""
from repro.kernels import ref as _ref  # noqa: F401  (registers portable hook impls)

__version__ = "1.0.0"
