"""RMSNorm — Pallas TPU kernel.

A bandwidth-bound elementwise+reduce op: the win over unfused XLA is a
single HBM pass (read x, write y) with the f32 mean-of-squares computed in
VMEM. Rows are tiled (BR, D): one block holds BR full rows so the reduction
never crosses blocks; D is the full feature dim (model-parallel shards pass
their local D — RMSNorm is row-wise so sharded features need a psum OUTSIDE
the kernel; the hook keeps feature dim unsharded per the ABI).

Grid: (rows/BR,). VMEM per block: BR*D*(2 bytes bf16 in + 4 bytes f32
scratch) — BR chosen so a (BR, D) f32 tile fits comfortably (<= ~4 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (BR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)  # (D,)
    o_ref[...] = (y * (1.0 + w[None, :])).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool | None = None) -> jax.Array:
    """Drop-in for the `rmsnorm` hook ABI (see kernels/ref.py)."""
    if interpret is None:
        interpret = compat.default_interpret()
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = 1
    for n in lead:
        rows *= n
    x2 = x.reshape(rows, d)
    # block size: keep the f32 working tile under ~4 MB of VMEM
    br = max(8, min(block_rows, rows, (4 << 20) // max(4 * d, 1)))
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out[:rows].reshape(*lead, d)
