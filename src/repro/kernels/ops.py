"""Accelerated-API implementations + hook registration.

Three system-optimized provider tiers per API (DESIGN.md §1 — the paper's
"system-optimized libraries" bound by OCI-style hooks at deploy time; full
matrix in docs/kernel-portability.md):

  * ``xla-blocked`` — memory-bounded pure-JAX implementations (blocked /
    online-softmax attention, chunkwise mLSTM). These lower to clean HLO on
    any XLA backend, keep peak memory O(block) instead of O(S^2), and are
    what the multi-pod dry-run binds (Pallas cannot lower for the CPU
    stand-in devices; on real TPU metal the pallas-tpu tier wins instead).
  * ``pallas-interpret`` — the SAME hand-tiled Pallas kernels forced into
    interpret mode: the Pallas interpreter emulates the grid/BlockSpec/
    scratch machinery with pure-JAX ops, so the kernels' tiling logic runs
    (and is CI-exercised) on any backend, at emulation speed.
  * ``pallas-tpu`` — hand-tiled Pallas TPU kernels (flash_attention,
    decode_attention, rmsnorm, rglru scan, moe grouped matmul, chunked
    mLSTM), validated against kernels/ref.py oracles in interpret mode.

Priorities: pallas-tpu (20) > pallas-interpret (15) > xla-blocked (10) >
portable reference (0).

Each Pallas-backed tier registers a *probe* (core/hooks.py): a tiny
candidate kernel compiled and run exactly the way the tier would execute on
the target. ``bind(profile, probe=True)`` rejects tiers whose probe fails —
so a JAX API-vintage mismatch (kernels/compat.py) degrades to the next tier
instead of crashing a deployed program mid-trace.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hooks
from repro.kernels import decode_attention as _dec_pallas
from repro.kernels import flash_attention as _fa_pallas
from repro.kernels import moe_gmm as _gmm_pallas
from repro.kernels import paged_attention as _paged_pallas
from repro.kernels import ref
from repro.kernels import rmsnorm as _rms_pallas

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blocked (memory-efficient) attention — pure JAX, O(bq*bk) live logits
# ---------------------------------------------------------------------------
def blocked_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
    block_q: int = 256,
    block_k: int = 512,
) -> jax.Array:
    """Online-softmax attention in pure jnp: outer scan over query blocks
    (rematerialized in backward), inner scan over kv blocks carrying
    (m, l, acc). GQA is handled by head grouping — the kv heads are never
    materially expanded. Same ABI as kernels/ref.py::attention.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA latent-space decode)
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5

    if sq * skv <= 2048 * 2048:
        # small problem: the plain oracle is cheaper than the scan machinery
        return ref.attention(
            q, k, v, causal=causal, window=window, scale=scale,
            logit_softcap=logit_softcap)

    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk

    # (B, Hkv, G, S, D) layout: group dim keeps GQA unexpanded
    qt = q.transpose(0, 2, 1, 3).reshape(b, hkv, g, sq, d)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, Skv, D)
    vt = v.transpose(0, 2, 1, 3)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (sq + pad_q) // bq
    nk = (skv + pad_k) // bk
    offset = skv - sq  # suffix alignment of queries

    def q_block(i):
        qi = jax.lax.dynamic_slice_in_dim(qt, i * bq, bq, axis=3)
        qi = qi.astype(jnp.float32) * scale
        qpos = i * bq + jax.lax.iota(jnp.int32, bq) + offset  # (bq,)

        def kv_step(carry, j):
            m_prev, l_prev, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(kt, j * bk, bk, axis=2)
            vj = jax.lax.dynamic_slice_in_dim(vt, j * bk, bk, axis=2)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qi, kj.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            if logit_softcap is not None:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            kpos = j * bk + jax.lax.iota(jnp.int32, bk)
            mask = (kpos < skv)[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_prev - m_cur)
            p = jnp.exp(s - m_cur[..., None])
            l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            return (m_cur, l_cur, acc), None

        m0 = jnp.full((b, hkv, g, bq), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32))
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked (padded) rows
        return (acc / l[..., None]).astype(q.dtype)  # (B,Hkv,G,bq,Dv)

    # named_scope marks this region in HLO op_name metadata: the roofline
    # walker credits its score-matrix dots as VMEM-resident (the deployed
    # pallas-tpu tier is flash attention; see hlo_cost._KERNEL_REGION_RE)
    with jax.named_scope("fused_attention_kernel"):
        blocks = jax.lax.map(jax.checkpoint(q_block),
                             jnp.arange(nq, dtype=jnp.int32))
    # (nq, B, Hkv, G, bq, Dv) -> (B, Hq, Sq, Dv) -> (B, Sq, Hq, Dv)
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, hkv * g, nq * bq, dv)
    return out[:, :, :sq].transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# Chunkwise-parallel mLSTM — pure JAX, O(C^2) live scores per chunk
# ---------------------------------------------------------------------------
def mlstm_chunkwise(
    q: jax.Array,  # (B, S, H, Dh)
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,  # (B, S, H) pre-activation
    f_gate: jax.Array,
    *,
    chunk: int = 256,
) -> jax.Array:
    """Chunkwise-parallel stabilized mLSTM, matching kernels/ref.py::mlstm.

    Sequential lax.scan over S/C chunks carrying the (C, n, m) matrix-memory
    state; inside a chunk the quadratic part is a (C x C) block — the same
    decomposition the official xLSTM kernels use, adapted to XLA (the Pallas
    TPU version lives in kernels/mlstm_chunk.py).
    """
    b, s, h, dh = q.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        # padded steps must not contribute: i = -inf, f = +inf (keep state)
        i_gate = i_gate.at[:, s:].set(-1e30)
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=30.0)
    sp = s + pad
    nc = sp // c

    # (B, NC, C, H, Dh) chunked views, f32 compute
    ch = lambda a: a.reshape(b, nc, c, *a.shape[2:]).astype(jnp.float32)
    qc, kc, vc = ch(q) * dh**-0.5, ch(k), ch(v)
    ic, fc = ch(i_gate), ch(f_gate)
    log_f = jax.nn.log_sigmoid(fc)  # (B, NC, C, H)
    F = jnp.cumsum(log_f, axis=2)  # inclusive within-chunk prefix sums
    a_t = ic - F  # (B, NC, C, H)

    tpos = jnp.arange(c)[:, None]
    spos = jnp.arange(c)[None, :]
    causal = (spos <= tpos)[None, :, :, None]  # (1, C, C, 1)

    def chunk_step(carry, xs):
        C_prev, n_prev, m_prev = carry  # (B,H,Dh,Dh), (B,H,Dh), (B,H)
        qj, kj, vj, ij, Fj, aj = xs  # (B,C,H,Dh) x3, (B,C,H) x3
        # running stabilizer: M_t = max(cummax_s<=t (i_s - F_s), m_prev)
        M = jnp.maximum(jax.lax.cummax(aj, axis=1), m_prev[:, None, :])
        m_t = Fj + M  # (B,C,H) — the recurrent m_t
        # intra-chunk: D[t,s] = exp(i_s - F_s - M_t) for s<=t
        log_d = aj[:, None, :, :] - M[:, :, None, :]  # (B,T,S,H)
        d = jnp.where(causal, jnp.exp(log_d), 0.0)
        scores = jnp.einsum("bthd,bshd->btsh", qj, kj) * d
        num_intra = jnp.einsum("btsh,bshv->bthv", scores, vj)
        den_intra = jnp.sum(scores, axis=2)  # (B,T,H)
        # inter-chunk: coeff_t = exp(m_prev - M_t)
        w_in = jnp.exp(m_prev[:, None, :] - M)  # (B,C,H)
        num_inter = jnp.einsum("bthd,bhdv->bthv", qj, C_prev) * w_in[..., None]
        den_inter = jnp.einsum("bthd,bhd->bth", qj, n_prev) * w_in
        num = num_intra + num_inter
        den = den_intra + den_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        out = num / den[..., None]
        # end-of-chunk state at stabilizer m_T = F_C + M_C
        M_c = M[:, -1, :]  # (B,H)
        F_c = Fj[:, -1, :]
        w_state = jnp.exp(aj - M_c[:, None, :])  # (B,C,H): i_s - F_s - M_C
        C_new = jnp.exp(m_prev - M_c)[:, :, None, None] * C_prev + jnp.einsum(
            "bsh,bshd,bshv->bhdv", w_state, kj, vj)
        n_new = jnp.exp(m_prev - M_c)[:, :, None] * n_prev + jnp.einsum(
            "bsh,bshd->bhd", w_state, kj)
        m_new = F_c + M_c
        return (C_new, n_new, m_new), out

    C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), _NEG_INF, jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (qc, kc, vc, ic, F, a_t))
    with jax.named_scope("fused_mlstm_kernel"):
        _, outs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, h, dh)
    return out[:, :s].astype(q.dtype)



# ---------------------------------------------------------------------------
# Pallas wrappers (jit'd, ABI == ref)
# ---------------------------------------------------------------------------
def pallas_attention(q, k, v, *, causal=True, window=None, scale=None,
                     logit_softcap=None):
    return _fa_pallas.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        logit_softcap=logit_softcap)


def pallas_decode_attention(q, k_cache, v_cache, *, lengths=None, window=None,
                            scale=None, logit_softcap=None):
    return _dec_pallas.decode_attention(
        q, k_cache, v_cache, lengths=lengths, window=window, scale=scale,
        logit_softcap=logit_softcap)


# ---------------------------------------------------------------------------
# Interpret-tier wrappers (Pallas kernels pinned to the interpreter, so the
# hand-tiled grid/BlockSpec code runs on CPU/GPU hosts — and on CPU CI)
# ---------------------------------------------------------------------------
def interpret_attention(q, k, v, *, causal=True, window=None, scale=None,
                        logit_softcap=None):
    return _fa_pallas.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale,
        logit_softcap=logit_softcap, interpret=True)


def interpret_decode_attention(q, k_cache, v_cache, *, lengths=None,
                               window=None, scale=None, logit_softcap=None):
    return _dec_pallas.decode_attention(
        q, k_cache, v_cache, lengths=lengths, window=window, scale=scale,
        logit_softcap=logit_softcap, interpret=True)


def pallas_paged_decode_attention(q, k_pool, v_pool, block_tables, *,
                                  lengths=None, window=None, scale=None,
                                  logit_softcap=None):
    return _paged_pallas.paged_decode_attention(
        q, k_pool, v_pool, block_tables, lengths=lengths, window=window,
        scale=scale, logit_softcap=logit_softcap)


def interpret_paged_decode_attention(q, k_pool, v_pool, block_tables, *,
                                     lengths=None, window=None, scale=None,
                                     logit_softcap=None):
    return _paged_pallas.paged_decode_attention(
        q, k_pool, v_pool, block_tables, lengths=lengths, window=window,
        scale=scale, logit_softcap=logit_softcap, interpret=True)


def interpret_rmsnorm(x, weight, *, eps=1e-6):
    return _rms_pallas.rmsnorm(x, weight, eps=eps, interpret=True)


def interpret_moe_mlp(expert_inputs, w_gate, w_up, w_down):
    return _gmm_pallas.moe_mlp(expert_inputs, w_gate, w_up, w_down,
                               interpret=True)


# ---------------------------------------------------------------------------
# Deploy-time probes: compile + run a TINY candidate kernel per tier, in the
# mode the tier would actually execute on the target. Failures (e.g. a
# pltpu API rename the shim cannot paper over) reject the tier at bind time.
# Probe tiles are (8, 128) — the f32 minimum Mosaic tile — so a compiled-
# Mosaic probe on TPU metal sees the same tile constraints the full-size
# kernels do and cannot be falsely rejected for sub-minimum blocks.
# ---------------------------------------------------------------------------
def _probe_args_attn():
    z = jnp.zeros((1, 8, 8, 128), jnp.float32)
    return z, jnp.zeros((1, 8, 1, 128), jnp.float32), jnp.zeros(
        (1, 8, 1, 128), jnp.float32)


def _probe_flash(interpret):
    def probe(profile):
        q, k, v = _probe_args_attn()
        _fa_pallas.flash_attention(
            q, k, v, block_q=8, block_k=8, interpret=interpret
        ).block_until_ready()
    return probe


def _probe_decode(interpret):
    def probe(profile):
        q = jnp.zeros((1, 8, 128), jnp.float32)
        kc = jnp.zeros((1, 8, 1, 128), jnp.float32)
        _dec_pallas.decode_attention(
            q, kc, kc, block_k=8, interpret=interpret).block_until_ready()
    return probe


def _probe_paged_decode(interpret):
    def probe(profile):
        q = jnp.zeros((1, 8, 128), jnp.float32)
        pool = jnp.zeros((2, 8, 1, 128), jnp.float32)
        bt = jnp.ones((1, 1), jnp.int32)
        _paged_pallas.paged_decode_attention(
            q, pool, pool, bt, interpret=interpret).block_until_ready()
    return probe


def _probe_rmsnorm(interpret):
    def probe(profile):
        x = jnp.zeros((8, 128), jnp.float32)
        w = jnp.zeros((128,), jnp.float32)
        _rms_pallas.rmsnorm(
            x, w, block_rows=8, interpret=interpret).block_until_ready()
    return probe


def _probe_moe(interpret):
    def probe(profile):
        x = jnp.zeros((1, 8, 128), jnp.float32)
        w = jnp.zeros((1, 128, 128), jnp.float32)
        _gmm_pallas.moe_swiglu_hidden(
            x, w, w, block_c=8, block_f=128, block_k=128, interpret=interpret
        ).block_until_ready()
    return probe


def _probe_blocked(profile):
    q, k, v = _probe_args_attn()
    blocked_attention(q, k, v, block_q=8, block_k=8).block_until_ready()


# interpret=None lets each kernel pick its own execution mode for the target
# backend (compiled Mosaic on TPU metal, interpreter elsewhere) — the probe
# then exercises exactly the path the bound tier will take.
_TPU_MODE = None
_INTERP_MODE = True


# ---------------------------------------------------------------------------
# Registration
# ---------------------------------------------------------------------------
def _is_tpu(profile: Any) -> bool:
    return getattr(profile, "chip", "").startswith("tpu") and profile.supports(
        "pallas-tpu")


def _is_interp(profile: Any) -> bool:
    return profile.supports("pallas-interpret")


def _is_xla(profile: Any) -> bool:
    return profile.supports("xla-blocked") or _is_tpu(profile) or _is_interp(
        profile)


def _register() -> None:
    reg = hooks.register_impl
    impls = {n for api in hooks.list_apis()
             for n in hooks.available_impls(api)}
    if "xla-blocked" in impls:
        return  # idempotent
    reg("attention", "xla-blocked", blocked_attention,
        supports=_is_xla, priority=10, probe=_probe_blocked)
    reg("attention", "pallas-interpret", interpret_attention,
        supports=_is_interp, priority=15, probe=_probe_flash(_INTERP_MODE))
    reg("attention", "pallas-tpu", pallas_attention,
        supports=_is_tpu, priority=20, probe=_probe_flash(_TPU_MODE))
    reg("decode_attention", "pallas-interpret", interpret_decode_attention,
        supports=_is_interp, priority=15, probe=_probe_decode(_INTERP_MODE))
    reg("decode_attention", "pallas-tpu", pallas_decode_attention,
        supports=_is_tpu, priority=20, probe=_probe_decode(_TPU_MODE))
    reg("paged_decode_attention", "pallas-interpret",
        interpret_paged_decode_attention, supports=_is_interp, priority=15,
        probe=_probe_paged_decode(_INTERP_MODE))
    reg("paged_decode_attention", "pallas-tpu", pallas_paged_decode_attention,
        supports=_is_tpu, priority=20, probe=_probe_paged_decode(_TPU_MODE))
    reg("mlstm", "xla-blocked", mlstm_chunkwise,
        supports=_is_xla, priority=10)
    reg("rmsnorm", "pallas-interpret", interpret_rmsnorm,
        supports=_is_interp, priority=15, probe=_probe_rmsnorm(_INTERP_MODE))
    reg("rmsnorm", "pallas-tpu", _rms_pallas.rmsnorm,
        supports=_is_tpu, priority=20, probe=_probe_rmsnorm(_TPU_MODE))
    reg("moe_mlp", "pallas-interpret", interpret_moe_mlp,
        supports=_is_interp, priority=15, probe=_probe_moe(_INTERP_MODE))
    reg("moe_mlp", "pallas-tpu", _gmm_pallas.moe_mlp,
        supports=_is_tpu, priority=20, probe=_probe_moe(_TPU_MODE))


_register()
