"""JAX/XLA version shim — the adapter between our kernels and the vintage of
the installed toolchain.

The XaaS portability contract (docs/kernel-portability.md) says a container
must specialize to *whatever the target platform actually provides*. In
practice the fastest-moving part of the platform is not the hardware but the
JAX/Pallas/XLA API surface itself: ``pltpu.CompilerParams`` was named
``TPUCompilerParams`` for several releases, ``PrefetchScalarGridSpec`` comes
and goes, and ``Compiled.cost_analysis()`` has returned (a) a dict, (b) a
one-element list of dicts, and (c) nothing, depending on version and backend.

Every kernel and every cost-model consumer goes through this module instead
of touching the moving targets directly, so a version bump degrades into a
*probe failure + tier fallback* (core/hooks.py) rather than an
``AttributeError`` at trace time deep inside a deployed program — which is
exactly what happened to the seed's 34 red kernel tests.

Nothing in here may assume a TPU is attached: all helpers must resolve at
import time on any XLA host.
"""
from __future__ import annotations

import inspect
from typing import Any, Mapping

import jax
from jax.experimental import pallas as pl  # noqa: F401  (re-exported surface)
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "TPU_COMPILER_PARAMS_CLS",
    "tpu_compiler_params",
    "prefetch_scalar_grid_spec",
    "default_interpret",
    "normalize_cost_analysis",
    "xla_cost_analysis",
    "vmem",
    "smem_space",
]


# ---------------------------------------------------------------------------
# Compiler params: pltpu.CompilerParams (new) vs pltpu.TPUCompilerParams (old)
# ---------------------------------------------------------------------------
TPU_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams", None)

_CP_FIELDS: frozenset[str] = frozenset(
    inspect.signature(TPU_COMPILER_PARAMS_CLS).parameters
) if TPU_COMPILER_PARAMS_CLS is not None else frozenset()


def tpu_compiler_params(**kwargs: Any):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    Accepts the union of fields across versions and silently drops the ones
    the installed class does not know (e.g. ``dimension_semantics`` moved
    around between releases); returns ``None`` — which ``pallas_call``
    accepts as "no params" — when no params class exists at all.
    """
    if TPU_COMPILER_PARAMS_CLS is None:
        return None
    accepted = {k: v for k, v in kwargs.items() if k in _CP_FIELDS}
    return TPU_COMPILER_PARAMS_CLS(**accepted)


# ---------------------------------------------------------------------------
# Scalar-prefetch grid spec (SMEM operands, e.g. decode lengths)
# ---------------------------------------------------------------------------
def prefetch_scalar_grid_spec(
    *,
    num_scalar_prefetch: int,
    grid: tuple[int, ...],
    in_specs: list,
    out_specs,
    scratch_shapes: list,
):
    """``pltpu.PrefetchScalarGridSpec`` where available.

    When a future version drops it, raise ``NotImplementedError`` so the
    deploy-time probe rejects the tier and dispatch falls back — instead of
    an AttributeError escaping mid-trace.
    """
    cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
    if cls is None:
        raise NotImplementedError(
            "this jax version has no pltpu.PrefetchScalarGridSpec; "
            "the pallas decode tier cannot bind")
    return cls(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )


# ---------------------------------------------------------------------------
# Memory spaces
# ---------------------------------------------------------------------------
def vmem(shape: tuple[int, ...], dtype) -> Any:
    """A VMEM scratch allocation (``pltpu.VMEM`` across versions)."""
    return pltpu.VMEM(shape, dtype)


def smem_space() -> Any:
    """The SMEM memory-space tag for scalar BlockSpecs."""
    return pltpu.SMEM


# ---------------------------------------------------------------------------
# Interpret-mode default
# ---------------------------------------------------------------------------
def default_interpret() -> bool:
    """Pallas TPU kernels interpret (pure-JAX emulation) off TPU metal."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# XLA cost_analysis normalization
# ---------------------------------------------------------------------------
def normalize_cost_analysis(raw: Any) -> dict:
    """Normalize ``jax.stages.Compiled.cost_analysis()`` output to one dict.

    Formats seen in the wild:
      * ``dict`` — current jax;
      * ``[dict]`` — one dict per partition, older jax (calling ``dict()`` on
        it iterates the inner dict's KEYS and dies with "dictionary update
        sequence element #0 has length 7");
      * ``None`` / ``[]`` — backends without a cost model.
    """
    if raw is None:
        return {}
    if isinstance(raw, Mapping):
        return dict(raw)
    if isinstance(raw, (list, tuple)):
        if not raw:
            return {}
        first = raw[0]
        if isinstance(first, Mapping):
            return dict(first)
        # a genuine sequence of (key, value) pairs
        if isinstance(first, (list, tuple)) and len(first) == 2:
            return dict(raw)
    raise TypeError(
        f"unrecognized cost_analysis() format: {type(raw).__name__}")


def xla_cost_analysis(compiled: Any) -> dict:
    """``compiled.cost_analysis()`` normalized; ``{}`` if unsupported."""
    try:
        raw = compiled.cost_analysis()
    except NotImplementedError:
        return {}
    return normalize_cost_analysis(raw)
