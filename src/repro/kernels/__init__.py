# Pallas TPU kernels for the XaaS system-optimized hook implementations.
# ref.py holds the portable (pure-jnp) oracles; ops.py registers the
# system-optimized tiers (xla-blocked + pallas-tpu).
from repro.kernels import ops, ref  # noqa: F401
