"""Flash attention (training/prefill) — Pallas TPU kernel.

TPU-native adaptation of the GPU flash-attention insight (DESIGN.md §1): the
point that transfers is *online-softmax tiling so the S×S score matrix never
touches HBM*; what changes for TPU is the blocking. Blocks are MXU-shaped
((128, head_dim) q tiles against (BK, head_dim) kv tiles, BK a multiple of
128), scratch accumulators live in VMEM across the sequential kv grid axis,
and GQA is handled by an index map that points each q-head block at its kv
head — no repeated kv in HBM (the jnp oracle materializes the expansion; the
kernel never does).

Grid: (batch, q_heads, Sq/BQ, Skv/BK), kv axis innermost/sequential
("arbitrary") so the VMEM scratch (m, l, acc) carries across it. Causal
blocks strictly above the diagonal are skipped via pl.when (zero work, not
just masked). Local-attention windows additionally skip blocks entirely left
of the window.

Supports: causal (suffix-aligned, Sq <= Skv), sliding window, logit softcap
(gemma/granite-style), GQA/MQA. Masked/padded kv tail handled by masking
against the true Skv (wrapper pads to block multiples).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

_NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,  # blocks
    m_scr, l_scr, acc_scr,  # VMEM scratch carried over the kv axis
    *, scale: float, causal: bool, window: int | None,
    softcap: float | None, sq: int, skv: int, bq: int, bk: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this block's rows/cols (suffix-aligned queries)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: causal => kv block start beyond last q row is dead;
    # window => kv block entirely left of the window is dead
    last_q = i * bq + bq - 1 + (skv - sq)
    first_q = i * bq + (skv - sq)
    run = jnp.bool_(True)
    if causal:
        run = run & (j * bk <= last_q)
    if window is not None:
        run = run & (j * bk + bk - 1 > first_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpos < skv  # padded kv tail
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]  # (BQ,)
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        # fully-masked rows: p == exp(-inf - m) -> 0; keep l from 0-div later
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur
        l_scr[...] = l_cur

    @pl.when(j == nj - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # dead rows (padding) -> 0 output
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "logit_softcap",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
    block_q: int = DEFAULT_BQ,
    block_k: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """Drop-in for the `attention` hook ABI (see kernels/ref.py)."""
    if interpret is None:
        interpret = compat.default_interpret()
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d**-0.5

    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (skv - 1).bit_length()))

    # layout: (B, H, S, D) so the head axis is a pure grid axis
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, bq)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bk)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bk)
    sq_p, skv_p = qt.shape[2], kt.shape[2]

    grid = (b, hq, sq_p // bq, skv_p // bk)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=logit_softcap, sq=sq, skv=skv, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, i, j, g=group: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            compat.vmem((bq,), jnp.float32),
            compat.vmem((bq,), jnp.float32),
            compat.vmem((bq, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)

    return out[:, :, :sq].transpose(0, 2, 1, 3)
