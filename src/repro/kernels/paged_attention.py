"""Paged decode attention (one query token vs a paged KV pool) — Pallas TPU.

vLLM-style PagedAttention adapted to the flash-decoding kernel in
``decode_attention.py``: the KV cache is no longer one contiguous
``(B, S, Hkv, D)`` region per batch row but a shared physical pool of
fixed-size pages ``(P, page, Hkv, D)``, and each row owns an int32 *block
table* mapping its logical page j to a physical page id. The kernel walks a
row's logical pages along a sequential grid axis; the page indirection
happens in the BlockSpec index map, which reads the scalar-prefetched block
table from SMEM — so the DMA engine streams exactly the pages the row owns,
in logical order, and the online-softmax recurrence is unchanged from the
contiguous kernel.

Grid: (B, Hkv, N) with N = pool pages per row (block-table width); logical
page j covers absolute positions [j*page, (j+1)*page). Pages entirely past
a row's ``length`` are skipped block-level (``pl.when`` — no HBM traffic for
the unallocated suffix, whose table entries point at the reserved null page
0). Window (local attention) masks positions < length - window.

TPU-metal note: the page size is the kv block size, so compiled-Mosaic use
wants page >= 8 (the f32 min sublane tile); the interpret tier has no such
constraint and is what CPU CI exercises.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

_NEG_INF = -1e30


def _paged_decode_kernel(
    len_ref,  # SMEM (B,)    valid lengths, scalar-prefetched
    bt_ref,  # SMEM (B, N)   block tables, scalar-prefetched
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, page, 1, D)  the physical page the index map gathered
    v_ref,
    o_ref,  # (1, 1, G, D)
    m_scr, l_scr, acc_scr,
    *, scale: float, window: int | None, softcap: float | None, page: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = j * page < length
    if window is not None:
        run = run & (j * page + page - 1 >= length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, page)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask &= kpos >= length - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(j == nj - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "logit_softcap", "interpret"))
def paged_decode_attention(
    q: jax.Array,  # (B, Hq, D)
    k_pool: jax.Array,  # (P, page, Hkv, D) shared physical page pool
    v_pool: jax.Array,
    block_tables: jax.Array,  # (B, N) int32 physical page ids
    *,
    lengths: jax.Array | None = None,  # (B,) int32
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Drop-in for the `paged_decode_attention` hook ABI (kernels/ref.py)."""
    if interpret is None:
        interpret = compat.default_interpret()
    b, hq, d = q.shape
    page, hkv = k_pool.shape[1], k_pool.shape[2]
    n = block_tables.shape[1]
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    if lengths is None:
        lengths = jnp.full((b,), n * page, jnp.int32)

    qt = q.reshape(b, hkv, g, d)
    grid = (b, hkv, n)
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, window=window,
        softcap=logit_softcap, page=page)

    out = pl.pallas_call(
        kernel,
        grid_spec=compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=2,  # lengths + block tables land in SMEM
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, g, d), lambda b_, h, j, lens, bt: (b_, h, 0, 0)),
                # the paging indirection: logical page j of row b_ is the
                # physical pool page the prefetched table names
                pl.BlockSpec(
                    (1, page, 1, d),
                    lambda b_, h, j, lens, bt: (bt[b_, j], 0, h, 0)),
                pl.BlockSpec(
                    (1, page, 1, d),
                    lambda b_, h, j, lens, bt: (bt[b_, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, g, d), lambda b_, h, j, lens, bt: (b_, h, 0, 0)),
            scratch_shapes=[
                compat.vmem((g,), jnp.float32),
                compat.vmem((g,), jnp.float32),
                compat.vmem((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), qt,
      k_pool, v_pool)

    return out.reshape(b, hq, d)
