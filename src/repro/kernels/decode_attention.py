"""Decode attention (one query token vs KV cache) — Pallas TPU kernel.

Flash-decoding adapted to TPU: at decode, the q "matrix" is a single token
per (batch, kv-head) — compute is trivially memory-bound on streaming the KV
cache HBM->VMEM. The kernel therefore:

  * processes all G = Hq/Hkv grouped query heads of one kv head together
    (one (G, D) q tile amortizes each streamed (BK, D) kv tile — the GQA
    arithmetic-intensity multiplier, which is the reason GQA exists),
  * walks the cache in (BK, D) blocks along a sequential grid axis with
    online-softmax scratch (same recurrence as prefill flash),
  * reads per-row valid `lengths` from SMEM and masks the tail block, and
    skips blocks entirely past `length` (pl.when — no HBM traffic for the
    unused cache suffix of short rows... the *block-level* early exit).

Grid: (B, Hkv, S/BK), kv axis sequential. Window (local attention) masks
positions < length - window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

_NEG_INF = -1e30
DEFAULT_BK = 256


def _decode_kernel(
    len_ref,  # SMEM (1,)   valid length for this batch row
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, 1, BK, D)
    v_ref,
    o_ref,  # (1, 1, G, D)
    m_scr, l_scr, acc_scr,
    *, scale: float, window: int | None, softcap: float | None, bk: int,
):
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    length = len_ref[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = j * bk < length
    if window is not None:
        run = run & (j * bk + bk - 1 >= length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, BK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        if window is not None:
            mask &= kpos >= length - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(j == nj - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "logit_softcap", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, Hq, D)
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,
    *,
    lengths: jax.Array | None = None,  # (B,) int32
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
    block_k: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """Drop-in for the `decode_attention` hook ABI (see kernels/ref.py)."""
    if interpret is None:
        interpret = compat.default_interpret()
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)

    bk = min(block_k, max(8, 1 << (s - 1).bit_length()))
    pad = (-s) % bk

    qt = q.reshape(b, hkv, g, d)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = s + pad

    grid = (b, hkv, sp // bk)
    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, softcap=logit_softcap, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid_spec=compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=0,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1,), lambda b_, h, j: (b_,),
                    memory_space=compat.smem_space()),
                pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b_, h, j: (b_, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            scratch_shapes=[
                compat.vmem((g,), jnp.float32),
                compat.vmem((g,), jnp.float32),
                compat.vmem((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qt, kt, vt)

    return out.reshape(b, hq, d)
