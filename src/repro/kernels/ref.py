"""Pure-jnp reference oracles for every accelerated API.

These are simultaneously:
  * the *portable* implementation each XaaS hook falls back to (the paper's
    lowest-common-denominator build that must run on any provider), and
  * the oracle every Pallas TPU kernel is validated against (allclose sweeps
    in tests/).

All numerics that matter (softmax, recurrences, norms) run in float32
regardless of input dtype and cast back, so portable and specialized paths
share one ABI contract: "inputs dtype X -> output dtype X, accumulation f32".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hooks

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis. x: (..., D), weight: (D,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# matmul (the BLAS hook)
# ---------------------------------------------------------------------------
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., K), w: (K, N) -> (..., N); f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (training / prefill)
# ---------------------------------------------------------------------------
def _gqa_expand(k: jax.Array, n_q_heads: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hq,D) by repeating each kv head Hq/Hkv times."""
    b, s, hkv, d = k.shape
    if hkv == n_q_heads:
        return k
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=2)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
) -> jax.Array:
    """Multi-head attention with GQA/MQA via head broadcast.

    q: (B, Sq, Hq, Dh); k, v: (B, Skv, Hkv, Dh), Hq % Hkv == 0.
    window: attend only to the last `window` positions (local attention).
    Query position i is aligned to key position i + (Skv - Sq) (suffix align).
    Returns (B, Sq, Hq, Dh) in q.dtype.
    """
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else dh**-0.5
    kx = _gqa_expand(k, hq).astype(jnp.float32)
    vx = _gqa_expand(v, hq).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) * scale
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (single query token against a KV cache)
# ---------------------------------------------------------------------------
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    lengths: jax.Array | None = None,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
) -> jax.Array:
    """One-token decode attention.

    q: (B, Hq, Dh); k_cache, v_cache: (B, S, Hkv, Dh);
    lengths: (B,) int32 — number of valid cache entries (current token is the
    last valid one). Positions >= length are masked. window limits attention
    to the trailing `window` valid positions.
    Returns (B, Hq, Dh).
    """
    b, hq, dh = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else dh**-0.5
    kx = _gqa_expand(k_cache, hq).astype(jnp.float32)
    vx = _gqa_expand(v_cache, hq).astype(jnp.float32)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kx) * scale
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    kpos = jnp.arange(s)[None, :]
    if lengths is None:
        lengths = jnp.full((b,), s, dtype=jnp.int32)
    mask = kpos < lengths[:, None]
    if window is not None:
        mask &= kpos >= (lengths[:, None] - window)
    logits = jnp.where(mask[:, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vx)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunk attention (suffix-continuation prefill against a KV cache)
# ---------------------------------------------------------------------------
def chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    positions: jax.Array,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
) -> jax.Array:
    """Multi-token continuation attention: a chunk of queries at absolute
    per-row ``positions`` attends a full KV cache (prefix entries restored
    from a prefix cache plus the chunk's own entries already scattered in).

    q: (B, Sq, Hq, Dh); k_cache, v_cache: (B, L, Hkv, Dh);
    positions: (B, Sq) int32 absolute position of each query.
    Cache slot j is visible to query i iff j <= positions[b, i]
    (causality over the whole cache, not just the chunk); window limits
    attention to the trailing `window` positions. Returns (B, Sq, Hq, Dh).
    """
    b, sq, hq, dh = q.shape
    lkv = k_cache.shape[1]
    scale = scale if scale is not None else dh**-0.5
    kx = _gqa_expand(k_cache, hq).astype(jnp.float32)
    vx = _gqa_expand(v_cache, hq).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx) * scale
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    kpos = jnp.arange(lkv)[None, None, :]
    qpos = positions[:, :, None]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[:, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vx)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# paged attention (KV in a shared physical page pool, per-row block tables)
# ---------------------------------------------------------------------------
def gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """Materialize per-row logical caches from a physical page pool.

    pool: (P, page, ...) fixed-size pages shared by every row;
    block_tables: (B, N) int32 — physical page id of row b's logical page j.
    Returns (B, N*page, ...): row b's logical cache in position order. With
    N*page == max_len this is bit-for-bit the contiguous (B, max_len, ...)
    cache the slot engine holds (unallocated table entries point at the
    reserved null page 0, whose garbage sits beyond `lengths` and is masked
    exactly like the slot cache's stale suffix).
    """
    g = pool[block_tables]  # (B, N, page, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def paged_decode_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    *,
    lengths: jax.Array | None = None,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
) -> jax.Array:
    """One-token decode attention over a paged KV pool.

    q: (B, Hq, Dh); k_pool, v_pool: (P, page, Hkv, Dh); block_tables: (B, N)
    int32. The portable tier gathers the pool into the contiguous layout and
    runs the contiguous oracle, so it is byte-identical to the slot engine's
    reference path — the parity anchor every paged tier is validated against.
    """
    k_cache = gather_pages(k_pool, block_tables)
    v_cache = gather_pages(v_pool, block_tables)
    return decode_attention(
        q, k_cache, v_cache, lengths=lengths, window=window, scale=scale,
        logit_softcap=logit_softcap)


def paged_chunk_attention(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block_tables: jax.Array,
    *,
    positions: jax.Array,
    window: int | None = None,
    scale: float | None = None,
    logit_softcap: float | None = None,
) -> jax.Array:
    """Chunked-prefill attention over a paged KV pool: q (B, Sq, Hq, Dh) at
    absolute ``positions`` (B, Sq) attends the gathered logical caches (the
    chunk's own entries already scattered into the pool)."""
    k_cache = gather_pages(k_pool, block_tables)
    v_cache = gather_pages(v_pool, block_tables)
    return chunk_attention(
        q, k_cache, v_cache, positions=positions, window=window, scale=scale,
        logit_softcap=logit_softcap)


# ---------------------------------------------------------------------------
# first-order linear recurrence:  h_t = a_t * h_{t-1} + x_t
# ---------------------------------------------------------------------------
def linear_recurrence(
    a: jax.Array, x: jax.Array, *, h0: jax.Array | None = None, axis: int = 1
) -> jax.Array:
    """Associative-scan linear recurrence along `axis` (default: time axis of
    (B, S, D) inputs). Returns all h_t, same shape as x, x.dtype."""
    af = a.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if h0 is not None:
        # fold h0 into the first step: h_1 = a_1 * h0 + x_1
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(0, 1)
        first = tuple(idx)
        xf = xf.at[first].add(af[first] * jnp.expand_dims(h0.astype(jnp.float32), axis))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, xf), axis=axis)
    return h.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE expert MLP over capacity-bucketed expert inputs (GShard layout)
# ---------------------------------------------------------------------------
def moe_mlp(
    expert_inputs: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
) -> jax.Array:
    """SwiGLU expert FFN applied per expert bucket.

    expert_inputs: (E, C, D); w_gate, w_up: (E, D, F); w_down: (E, F, D).
    Returns (E, C, D) in input dtype.
    """
    g = jnp.einsum("ecd,edf->ecf", expert_inputs, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", expert_inputs, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(expert_inputs.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, w_down, preferred_element_type=jnp.float32)
    return out.astype(expert_inputs.dtype)


# ---------------------------------------------------------------------------
# mLSTM chunkwise-parallel form (xLSTM) — full parallel O(S^2) oracle
# ---------------------------------------------------------------------------
def mlstm(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    i_gate: jax.Array,
    f_gate: jax.Array,
) -> jax.Array:
    """Stabilized parallel mLSTM (xLSTM eq. 20-26).

    q, k, v: (B, S, H, Dh); i_gate, f_gate: (B, S, H) pre-activation.
    Returns (B, S, H, Dh) in q.dtype.
    """
    b, s, h, dh = q.shape
    qf = q.astype(jnp.float32) * dh**-0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,S,H)
    log_f_cum = jnp.cumsum(log_f, axis=1)  # prefix sums inclusive
    # log decay D[t, s'] = (logfcum_t - logfcum_s') + i_s'   for s' <= t
    i_f32 = i_gate.astype(jnp.float32)
    log_d = (
        log_f_cum[:, :, None, :] - log_f_cum[:, None, :, :] + i_f32[:, None, :, :]
    )  # (B, T, S, H)
    tpos = jnp.arange(s)[:, None]
    spos = jnp.arange(s)[None, :]
    causal = (spos <= tpos)[None, :, :, None]
    log_d = jnp.where(causal, log_d, _NEG_INF)
    m = jnp.max(log_d, axis=2, keepdims=True)  # (B,T,1,H) row stabilizer
    d = jnp.exp(log_d - m)
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * d
    denom = jnp.maximum(jnp.abs(jnp.sum(scores, axis=2)), jnp.exp(-m[:, :, 0, :]))
    out = jnp.einsum("btsh,bshd->bthd", scores, vf) / denom[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Registration: these references ARE the portable implementations.
# ---------------------------------------------------------------------------
def _register() -> None:
    if "attention" in hooks.list_apis():
        return  # idempotent under re-import
    hooks.register_api(
        "rmsnorm",
        "rmsnorm(x(...,D), weight(D,), *, eps) -> (...,D); f32 accumulation",
        rmsnorm,
    )
    hooks.register_api("matmul", "matmul(x(...,K), w(K,N)) -> (...,N); f32 acc", matmul)
    hooks.register_api(
        "attention",
        "attention(q(B,Sq,Hq,D), k(B,Skv,Hkv,D), v, *, causal, window, scale,"
        " logit_softcap) -> (B,Sq,Hq,D)",
        attention,
    )
    hooks.register_api(
        "decode_attention",
        "decode_attention(q(B,Hq,D), k_cache(B,S,Hkv,D), v_cache, *, lengths(B,),"
        " window, scale, logit_softcap) -> (B,Hq,D)",
        decode_attention,
    )
    hooks.register_api(
        "chunk_attention",
        "chunk_attention(q(B,Sq,Hq,D), k_cache(B,L,Hkv,D), v_cache, *,"
        " positions(B,Sq), window, scale, logit_softcap) -> (B,Sq,Hq,D)",
        chunk_attention,
    )
    hooks.register_api(
        "paged_decode_attention",
        "paged_decode_attention(q(B,Hq,D), k_pool(P,page,Hkv,D), v_pool,"
        " block_tables(B,N), *, lengths(B,), window, scale, logit_softcap)"
        " -> (B,Hq,D)",
        paged_decode_attention,
    )
    hooks.register_api(
        "paged_chunk_attention",
        "paged_chunk_attention(q(B,Sq,Hq,D), k_pool(P,page,Hkv,D), v_pool,"
        " block_tables(B,N), *, positions(B,Sq), window, scale,"
        " logit_softcap) -> (B,Sq,Hq,D)",
        paged_chunk_attention,
    )
    hooks.register_api(
        "linear_recurrence",
        "linear_recurrence(a(B,S,D), x(B,S,D), *, h0(B,D), axis) -> (B,S,D)",
        linear_recurrence,
    )
    hooks.register_api(
        "moe_mlp",
        "moe_mlp(expert_inputs(E,C,D), w_gate(E,D,F), w_up(E,D,F), w_down(E,F,D))"
        " -> (E,C,D); SwiGLU",
        moe_mlp,
    )
    hooks.register_api(
        "mlstm",
        "mlstm(q,k,v(B,S,H,D), i_gate(B,S,H), f_gate(B,S,H)) -> (B,S,H,D)",
        mlstm,
    )


_register()
