"""MoE grouped expert matmul (SwiGLU FFN) — Pallas TPU kernel.

The expert-major bucket layout (E, C, D) from the permutation-gather
dispatch makes the expert FFN a *grouped* matmul: E independent
(C, D) x (D, F) problems. The TPU adaptation (vs. CUDA grouped-GEMM):

  * the expert axis is a parallel grid dimension — each program owns one
    (expert, C-tile, F-tile) cell, so no dynamic gather of weight pointers
    (the CUDA trick) is needed: BlockSpec index maps select the expert's
    weight tile directly;
  * tiles are MXU-shaped (BC x BK @ BK x BF), accumulated in f32 VMEM
    scratch over the sequential K axis;
  * the SwiGLU nonlinearity (silu(x@Wg) * (x@Wu)) fuses into the same
    kernel: both gate and up projections read the SAME x tile while it is
    resident in VMEM — one HBM pass over the (E, C, D) buckets instead of
    XLA's two.

Grid: (E, C/BC, F/BF, D/BK); K innermost/sequential carrying (acc_g, acc_u).
Output is the hidden activation h = silu(g)*u (E, C, F); the down
projection is a second call or plain XLA einsum (it is a regular matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _swiglu_kernel(x_ref, wg_ref, wu_ref, h_ref, acc_g, acc_u, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[0]  # (BC, BK)
    acc_g[...] += jax.lax.dot_general(
        x, wg_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_u[...] += jax.lax.dot_general(
        x, wu_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        g = acc_g[...]
        h_ref[0] = (g * jax.nn.sigmoid(g) * acc_u[...]).astype(h_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_c", "block_f", "block_k", "interpret"))
def moe_swiglu_hidden(
    x: jax.Array,      # (E, C, D) expert input buckets
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,    # (E, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """h = silu(x @ w_gate) * (x @ w_up), grouped over experts. (E, C, F)."""
    if interpret is None:
        interpret = compat.default_interpret()
    e, c, d = x.shape
    f = w_gate.shape[-1]
    bc = min(block_c, c)
    bf = min(block_f, f)
    bk = min(block_k, d)

    def padto(a, axis, m):
        p = (-a.shape[axis]) % m
        if not p:
            return a
        w = [(0, 0)] * a.ndim
        w[axis] = (0, p)
        return jnp.pad(a, w)

    xp = padto(padto(x, 1, bc), 2, bk)
    wgp = padto(padto(w_gate, 1, bk), 2, bf)
    wup = padto(padto(w_up, 1, bk), 2, bf)
    cp, dp = xp.shape[1], xp.shape[2]
    fp = wgp.shape[2]
    nk = dp // bk
    grid = (e, cp // bc, fp // bf, nk)

    out = pl.pallas_call(
        functools.partial(_swiglu_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda ee, i, j, k: (ee, i, k)),
            pl.BlockSpec((1, bk, bf), lambda ee, i, j, k: (ee, k, j)),
            pl.BlockSpec((1, bk, bf), lambda ee, i, j, k: (ee, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ee, i, j, k: (ee, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, fp), x.dtype),
        scratch_shapes=[
            compat.vmem((bc, bf), jnp.float32),
            compat.vmem((bc, bf), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(xp, wgp, wup)
    return out[:, :c, :f]


def moe_mlp(expert_inputs: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Full expert FFN matching the `moe_mlp` hook ABI: the fused SwiGLU
    kernel + a grouped down-projection einsum."""
    h = moe_swiglu_hidden(expert_inputs, w_gate, w_up, interpret=interpret)
    out = jnp.einsum("ecf,efd->ecd", h, w_down,
                     preferred_element_type=jnp.float32)
    return out.astype(expert_inputs.dtype)
