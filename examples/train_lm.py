"""End-to-end training driver (deliverable (b)): train a ~100M-param LM for
a few hundred steps with checkpointing + fault tolerance, through the same
launcher production uses.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen2-0.5b]

The default config is the qwen2-0.5b family at ~100M scale (wider than the
smoke config: real vocab slice, 8 layers, d=256), trained on the synthetic
zipf+copy stream — loss must drop below the unigram entropy floor, proving
the model learns the copy structure, not just token frequencies.
"""
import argparse
import dataclasses
import math
import pathlib
import tempfile

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.store import CheckpointStore
from repro.data import pipeline as datalib
from repro.ft.manager import FailureInjector, FTManager
from repro.training import train_step as ts


def build_100m(arch: str):
    base = configs.get_config(arch)
    return dataclasses.replace(
        base,
        name=base.name + "-100m",
        num_layers=8,
        d_model=256,
        num_heads=8,
        num_kv_heads=2,
        head_dim=32,
        d_ff=1024 if base.d_ff else 0,
        vocab_size=min(base.vocab_size, 32768),
        prefix=(),
        pattern=base.pattern,
        param_dtype="float32",
        activ_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fault-rate", type=float, default=0.01,
                    help="per-step simulated node-loss probability")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = build_100m(args.arch)
    n = cfg.param_counts()
    print(f"model: {cfg.name} — {n['total'] / 1e6:.1f}M params "
          f"({n['total_nonembed'] / 1e6:.1f}M non-embedding)")

    tcfg = ts.TrainConfig(
        microbatches=2,
        adamw=ts.opt.AdamWConfig(lr_peak=3e-3, warmup_steps=20,
                                 decay_steps=args.steps))
    data = datalib.SyntheticLM(datalib.DataConfig(
        global_batch=args.batch, seq_len=args.seq, vocab_size=cfg.vocab_size,
        seed=0))
    # no donation here: the FT manager may re-enter with the same initial
    # state after an early fault (before the first checkpoint exists)
    step_jit = jax.jit(ts.make_train_step(cfg, tcfg))

    root = args.ckpt_dir or tempfile.mkdtemp(prefix="xaas_train_")
    store = CheckpointStore(root)
    init = ts.init_train_state(jax.random.key(0), cfg, tcfg)
    history = []

    def make_step(mesh_size):
        start, state = 0, init
        if store.latest_step() is not None:
            state, meta = store.restore(init)
            start = int(meta["data_step"])
            print(f"  [ft] restored step {start} on mesh={mesh_size}")

        def one(state, i):
            b = data.batch(i)
            state, m = step_jit(state, {"tokens": b["tokens"],
                                        "labels": b["labels"]})
            if i % 20 == 0 or i == args.steps - 1:
                loss = float(m["loss"])
                history.append((i, loss))
                print(f"  step {i:4d} loss {loss:.4f} "
                      f"lr {float(m['lr']):.2e}")
            return state, m

        return one, state, start

    mgr = FTManager(
        make_step=make_step,
        save=lambda s, i: store.save(i, s, meta={"data_step": i}),
        injector=FailureInjector(seed=1, p_node_loss=args.fault_rate,
                                 straggler_p=0.02),
        ckpt_every=50, min_mesh=1)
    report = mgr.run(args.steps, mesh_size=4)
    store.wait()

    first, last = history[0][1], history[-1][1]
    # unigram entropy floor of the zipf distribution (nats)
    import numpy as np
    ranks = np.arange(1, cfg.vocab_size + 1)
    p = ranks ** -1.3
    p /= p.sum()
    floor = float(-(p * np.log(p)).sum())
    print(f"\ndone: {report.steps_done} steps, {report.restarts} restarts, "
          f"{report.mitigations} straggler mitigations")
    print(f"loss {first:.3f} -> {last:.3f} (unigram floor {floor:.3f})")
    assert last < first, "loss must decrease"
    print(f"checkpoints in {root}: steps {store.steps()}")


if __name__ == "__main__":
    main()
