"""XaaS multi-provider deployment example: ONE container recipe deployed to
two different provider profiles (the paper's core portability story).

The same traced program (the shipped "IR container") is specialized per
target: hook bindings differ (portable jnp vs blocked tier), and the
deployment compiler caches both stages — a warm re-deploy is ~instant.

    PYTHONPATH=src python examples/xaas_deploy.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import container as xc
from repro.core import hooks, recompile
from repro.models import transformer


def lm_container(cfg):
    """A performance-portable container for one assigned arch's forward."""
    b, s = 2, 64

    def fwd(params, tokens):
        logits, _ = transformer.forward(params, cfg, tokens)
        return logits

    def make_args(mesh):
        params = jax.eval_shape(
            lambda: transformer.init_model(jax.random.key(0), cfg))
        toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return (params, toks), {}, {}

    return xc.XContainer(name=f"lm-{cfg.name}",
                         entrypoints={"forward": (fwd, make_args)})


def main():
    cfg = configs.get_config("qwen2-0.5b-smoke")
    cont = lm_container(cfg)

    # provider A: the portability floor (pure jnp reference everywhere)
    floor = recompile.PORTABLE_CPU
    # provider B: a "system-optimized" site advertising the blocked tier
    optimized = dataclasses.replace(
        floor, name="cpu-blocked-site", providers=("xla-blocked",))

    deployments = {}
    for prof in (floor, optimized):
        t0 = time.perf_counter()
        dep = cont.deploy(prof)
        dt = time.perf_counter() - t0
        deployments[prof.name] = dep
        art = dep.artifact("forward")
        print(f"deployed {cont.name} -> {prof.name} in {dt:.2f}s | "
              f"hooks: attention={dep.providers()['attention']} | "
              f"flops={art.flops:.3g}")

    # warm re-deploy: the compiled artifact is cached per (IR, profile)
    t0 = time.perf_counter()
    cont.deploy(floor)
    print(f"warm re-deploy: {time.perf_counter() - t0:.4f}s (cache hit)")

    # same numerics across providers (the hook ABI contract)
    params = transformer.init_model(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    outs = {name: np.asarray(dep("forward", params, toks))
            for name, dep in deployments.items()}
    a, b = outs.values()
    print(f"cross-provider max |Δlogits| = {np.max(np.abs(a - b)):.2e}")
    assert np.max(np.abs(a - b)) < 1e-3
    print("xaas_deploy OK")


if __name__ == "__main__":
    main()
