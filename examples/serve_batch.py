"""Multi-tenant elastic serving example — the canonical fleet walkthrough.

Three tenants share an autoscaled fleet of leased serving replicas while two
BATCH training jobs coexist on the same cluster:

  * requests are placed by the affinity router (returning sessions stick to
    their replica; prompt buckets stay hot),
  * a traffic burst trips the SLO autoscaler, which acquires more SERVICE
    leases — preempting (checkpoint + requeue) a training job when the
    cluster is full,
  * the lull drains the extra replicas back to the minimum footprint and
    releases their leases, letting the training jobs resume from their
    checkpoints,
  * every served token is metered to the tenant whose request produced it,
    aggregated across replicas in one ledger.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen2-0.5b]
"""
import argparse
import time

import jax

from repro import configs
from repro.fleet import FleetConfig, FleetManager, SLO, bursty_trace, materialize
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--max-replicas", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch + "-smoke")
    params = transformer.init_model(jax.random.key(args.seed), cfg)

    trace = bursty_trace(
        seed=args.seed, duration_s=24.0, base_rate=0.3, burst_rate=8.0,
        bursts=((4.0, 12.0),),
        tenants={"acme": 0.5, "globex": 0.3, "initech": 0.2},
        prompt_median=8, prompt_lo=4, prompt_hi=16,
        max_new_lo=4, max_new_hi=8)
    reqs = materialize(trace, vocab_size=cfg.vocab_size, seed=args.seed + 1,
                       num_codebooks=(cfg.num_codebooks
                                      if cfg.frontend == "audio" else 0))

    fleet = FleetManager.build(
        cfg, params, chips=args.chips,
        fleet=FleetConfig(min_replicas=1, max_replicas=args.max_replicas,
                          slots=2, max_len=64, prompt_buckets=(8, 16),
                          tick_s=0.1, warm_boot_s=0.5, cold_boot_s=1.5),
        slo=SLO(p95_target_s=1.5, queue_high_per_slot=1.0,
                up_cooldown_s=1.0, down_cooldown_s=2.0, idle_drain_s=3.0),
        batch_jobs=[(1, 30), (1, 30)])

    t0 = time.perf_counter()
    report = fleet.run_trace(reqs)
    wall = time.perf_counter() - t0

    print(f"{report.served}/{report.requests} requests, {report.tokens} "
          f"tokens over {report.duration_s:.1f} virtual s "
          f"({wall:.1f}s real) | p50 {report.latency_p50_s:.2f}s "
          f"p99 {report.latency_p99_s:.2f}s")
    print(f"elasticity: {report.scale_ups} scale-ups / "
          f"{report.lease_releases} lease releases / "
          f"{report.preemptions} batch preemptions "
          f"({report.batch['resumes']} checkpoint-resumes), "
          f"{report.serving_chip_s:.1f} serving chip-seconds")
    print("timeline:")
    for t, what in fleet.timeline:
        print(f"  [{t:6.2f}s] {what}")
    print("router:", fleet.router.stats)
    meter = fleet.service.meter
    print("per-tenant ledger (aggregated across replicas):")
    for tenant in sorted(report.tokens_by_tenant):
        print(f"  {tenant:<10} {report.metered_by_tenant[tenant]:>5} tokens")
    print(f"  {'fleet-op':<10} {meter.total_steps('serve_decode', 'fleet-op'):>5} "
          f"decode steps billed (${meter.total_usd('fleet-op'):.6f})")

    assert report.served == report.requests
    assert report.reconciled, "per-tenant ledger must reconcile across replicas"
    assert report.scale_ups >= 1 and report.lease_releases >= 1
    meter.check_invariants()


if __name__ == "__main__":
    main()
