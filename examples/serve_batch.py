"""Batched serving example (deliverable (b)): continuous batching over mixed
request sizes, with FaaS-style metering per request batch.

    PYTHONPATH=src python examples/serve_batch.py [--arch qwen2-0.5b]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.accounting import Meter
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch + "-smoke")
    params = transformer.init_model(jax.random.key(0), cfg)
    engine = ServingEngine(cfg, params, slots=args.slots, max_len=128,
                           prompt_buckets=(16, 32, 64))
    meter = Meter()
    rng = np.random.default_rng(0)

    for i in range(args.requests):
        plen = int(rng.integers(4, 32))
        if cfg.frontend == "audio":
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.num_codebooks, plen), dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
        engine.submit(Request(
            request_id=i, prompt=prompt,
            max_new_tokens=int(rng.integers(4, args.max_new + 1)),
            sampling=SamplingConfig(temperature=args.temperature, top_k=40)))

    t0 = time.perf_counter()
    results = engine.run_to_completion()
    wall = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in results.values())
    meter.record(tenant="serve-demo", kind="decode",
                 steps=engine.stats["decode_steps"], chips=1, wall_s=wall)

    print(f"{len(results)}/{args.requests} requests, {toks} tokens in "
          f"{wall:.2f}s ({toks / wall:.1f} tok/s)")
    print(f"engine: {engine.stats['prefills']} prefills, "
          f"{engine.stats['decode_steps']} decode steps "
          f"(batching factor {toks / max(engine.stats['decode_steps'], 1):.2f} "
          f"tokens/step)")
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: {results[rid].tokens[:8]}...")
    print(f"billed: ${meter.total_usd():.6f}")
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
