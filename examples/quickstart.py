"""Quickstart: the XaaS pipeline end to end in ~60 lines.

Build a performance-portable container for a small LM, deploy it to the
portable profile, train a few steps through the metered invocation layer,
then serve a request — the paper's build → ship → specialize → invoke → bill
loop at laptop scale.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import hooks, invocation, recompile, scheduler
from repro.core.accounting import Meter
from repro.data import pipeline as datalib
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.training import train_step as ts


def main():
    # 1. pick an assigned architecture at smoke scale ------------------
    cfg = configs.get_config("qwen2-0.5b-smoke")
    tcfg = ts.TrainConfig(microbatches=2)
    print(f"arch={cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"params={cfg.param_counts()['total'] / 1e6:.1f}M")

    # 2. the provider control plane: cluster + metering ----------------
    cluster = scheduler.Cluster(chips=8)
    svc = invocation.InvocationService(cluster, Meter())

    # 3. train a few steps (the data plane is compiled XLA only) -------
    data = datalib.SyntheticLM(datalib.DataConfig(
        global_batch=8, seq_len=32, vocab_size=cfg.vocab_size))
    state = ts.init_train_state(jax.random.key(0), cfg, tcfg)
    step = jax.jit(ts.make_train_step(cfg, tcfg))
    for i in range(5):
        state, metrics = step(state, data.batch(i))
        print(f"  step {i}: loss={float(metrics['loss']):.4f}")

    # 4. hook bindings: the same model, portable vs blocked tier -------
    binding = hooks.bind(None, overrides={"attention": "xla-blocked"})
    with hooks.use(binding):
        logits, _ = transformer.forward(
            state["params"], cfg, data.batch(0)["tokens"])
    print(f"  forward under {binding.providers()['attention']} tier: "
          f"logits {logits.shape}")

    # 5. serve two requests with continuous batching -------------------
    eng = ServingEngine(cfg, state["params"], slots=2, max_len=64)
    eng.submit(Request(request_id=0, prompt=jnp.arange(8), max_new_tokens=5))
    eng.submit(Request(request_id=1, prompt=jnp.arange(4), max_new_tokens=5))
    results = eng.run_to_completion()
    for rid, r in sorted(results.items()):
        print(f"  request {rid}: generated {r.tokens}")

    # 6. the bill (fine-grained, from compiled truth) ------------------
    comp = recompile.DeploymentCompiler()
    x = jnp.ones((64, 64))
    art = comp.deploy(lambda a: a @ a, "mm", recompile.PORTABLE_CPU, args=(x,))
    svc.meter.record(tenant="quickstart", kind="mm", steps=1, chips=1,
                     wall_s=1e-3, artifact=art)
    print(f"  billed: ${svc.meter.total_usd('quickstart'):.6f} "
          f"({svc.meter.total_flop_s('quickstart'):.3g} FLOPs)")
    print("quickstart OK")


if __name__ == "__main__":
    main()
